"""Shared neural-net layers (pure JAX, pytree params, scan-friendly).

Conventions:
  * params are plain dicts of jnp arrays; layer stacks hold them with a
    leading (n_layers, ...) axis so the decoder can lax.scan over layers;
  * every attention variant supports three modes: full-sequence causal
    (train/prefill) and single-token decode against a KV cache;
  * shapes: x (B, T, D); caches (B, S, n_kv, hd).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.ctx import batch_axes, constrain

Params = Dict[str, jax.Array]

_NEG_INF = -1e30


# ---------------------------------------------------------------- norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


# ----------------------------------------------------------------- rope
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 1e4) -> jax.Array:
    """x: (..., T, H, hd); positions: (..., T)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., T, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention
def _causal_mask(q_len: int, k_len: int, q_offset: int = 0,
                 window: int = 0) -> jax.Array:
    """(q_len, k_len) boolean mask; window > 0 adds a sliding window."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(k_len)[None, :]
    mask = k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    return mask


def attention_scores(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array) -> jax.Array:
    """q: (B,T,H,hd) k/v: (B,S,Hkv,hd) grouped-query attention core.

    `mask` is (T, S) shared across the batch, or (B, T, S) when rows mask
    different key ranges (mixed-length left-padded batches / per-slot
    continuous-batching timelines)."""
    b, t, h, hd = q.shape
    hkv = k.shape[2]
    group = h // hkv
    qg = q.reshape(b, t, hkv, group, hd)
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, k) / np.sqrt(hd)
    m = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
    scores = jnp.where(m, scores.astype(jnp.float32), _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, v)
    return out.reshape(b, t, h, hd)


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0          # 0 = full


def init_attention(rng, d_model: int, spec: AttnSpec,
                   dtype=jnp.bfloat16) -> Params:
    keys = jax.random.split(rng, 4)
    h, kv, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    s = float(1.0 / np.sqrt(d_model))
    p = {
        "wq": jax.random.normal(keys[0], (d_model, h * hd), dtype) * s,
        "wk": jax.random.normal(keys[1], (d_model, kv * hd), dtype) * s,
        "wv": jax.random.normal(keys[2], (d_model, kv * hd), dtype) * s,
        "wo": jax.random.normal(keys[3], (h * hd, d_model), dtype) *
        (float(1.0 / np.sqrt(h * hd))),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    if spec.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(p: Params, x: jax.Array, spec: AttnSpec, positions):
    b, t, _ = x.shape
    h, kv, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    q = constrain(x @ p["wq"], batch_axes(), None, "model")
    k = constrain(x @ p["wk"], batch_axes(), None, "model")
    v = constrain(x @ p["wv"], batch_axes(), None, "model")
    if spec.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, t, h, hd)
    k = k.reshape(b, t, kv, hd)
    v = v.reshape(b, t, kv, hd)
    if spec.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, spec.rope_theta)
    k = apply_rope(k, positions, spec.rope_theta)
    return q, k, v


# sequences at/above this length take the memory-bounded flash path
FLASH_THRESHOLD = 2048
DECODE_FLASH_THRESHOLD = 8192


def _attend(q, k, v, spec: AttnSpec) -> jax.Array:
    t = q.shape[1]
    if t >= FLASH_THRESHOLD:
        from repro.models.flash import flash_full
        return flash_full(q, k, v, window=spec.sliding_window)
    mask = _causal_mask(t, t, window=spec.sliding_window)
    return attention_scores(q, k, v, mask)


def attention_full(p: Params, x: jax.Array, spec: AttnSpec) -> jax.Array:
    """Causal self-attention over the whole sequence (train / prefill)."""
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    q, k, v = _project_qkv(p, x, spec, positions)
    out = _attend(q, k, v, spec)
    return out.reshape(b, t, -1) @ p["wo"]


def attention_decode(p: Params, x: jax.Array, spec: AttnSpec,
                     cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array, start=None) -> Tuple[jax.Array,
                                                          jax.Array,
                                                          jax.Array]:
    """One-token decode. x: (B,1,D); cache: (B,S,kv,hd).

    `pos` is a shared scalar, or a (B,) vector when rows sit at different
    timeline positions (continuous batching: each slot has its own clock).
    `start` is an optional (B,) vector of first-valid cache positions; keys
    below it are masked out (left-padded batches).  The flash-decode path
    only handles the shared-scalar unpadded case, so per-row timelines fall
    back to the masked dense path regardless of cache length.
    """
    b, _, _ = x.shape
    s = cache_k.shape[1]
    per_row = jnp.ndim(pos) == 1
    pos_b = pos if per_row else jnp.broadcast_to(pos[None], (b,))
    q, k, v = _project_qkv(p, x, spec, pos_b[:, None])
    if per_row:
        rows = jnp.arange(b)
        cache_k = cache_k.at[rows, pos_b].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[rows, pos_b].set(v[:, 0].astype(cache_v.dtype))
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    if s >= DECODE_FLASH_THRESHOLD and not per_row and start is None:
        from repro.models.flash import flash_decode
        out = flash_decode(q, cache_k.astype(q.dtype),
                           cache_v.astype(q.dtype), pos,
                           window=spec.sliding_window)
    else:
        k_pos = jnp.arange(s)
        mask = k_pos[None, :] <= pos_b[:, None]                  # (B, S)
        if spec.sliding_window > 0:
            mask &= k_pos[None, :] > pos_b[:, None] - spec.sliding_window
        if start is not None:
            mask &= k_pos[None, :] >= start[:, None]
        out = attention_scores(q, cache_k.astype(q.dtype),
                               cache_v.astype(q.dtype), mask[:, None, :])
    return out.reshape(b, 1, -1) @ p["wo"], cache_k, cache_v


# ------------------------------------------------------------------- mlp
def init_mlp(rng, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = float(1.0 / np.sqrt(d_model))
    s_out = float(1.0 / np.sqrt(d_ff))
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), dtype) * s_out,
    }


def mlp(p: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(constrain(x @ p["w_gate"], batch_axes(), None, "model"))
    h = h * constrain(x @ p["w_up"], batch_axes(), None, "model")
    return constrain(h @ p["w_down"], batch_axes(), None, None)


def attention_prefill(p: Params, x: jax.Array, spec: AttnSpec, start=None
                      ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Causal self-attention returning (out, (k, v)) for cache filling.

    `start` is an optional (B,) vector of first real token positions for
    left-padded batches; keys before a row's start never enter its softmax,
    so a padded prompt attends exactly as it would alone (RoPE phases are
    relative, so the constant position shift cancels in the scores)."""
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    q, k, v = _project_qkv(p, x, spec, positions)
    if start is None:
        out = _attend(q, k, v, spec)
    else:
        mask = _causal_mask(t, t, window=spec.sliding_window)    # (t, t)
        mask = mask[None] & (jnp.arange(t)[None, None, :] >=
                             start[:, None, None])               # (B, t, t)
        out = attention_scores(q, k, v, mask)
    return out.reshape(b, t, -1) @ p["wo"], (k, v)
