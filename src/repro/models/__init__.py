from repro.models.config import ModelConfig
from repro.models.registry import (ALIASES, ARCH_IDS, build, build_model,
                                   get_config)

__all__ = ["ModelConfig", "ALIASES", "ARCH_IDS", "build", "build_model",
           "get_config"]
