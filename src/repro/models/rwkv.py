"""RWKV6 ("Finch") decoder stack — attention-free, O(1)-state decode.

The paper's channel-partitioning technique applies to the r/k/v/g/o
projections and the channel-mix FFN (all plain matmuls); the WKV recurrence
itself is sequential and is never split (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.models.ssm import (init_rwkv6, init_rwkv_channel_mix,
                              rwkv6_mix, rwkv_channel_mix,
                              rwkv6_state_shapes)

Params = Dict[str, Any]


class RWKVModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = {"bfloat16": jnp.bfloat16,
                      "float32": jnp.float32}[cfg.dtype]

    def init(self, rng) -> Params:
        cfg = self.cfg
        k_emb, k_out, k_blocks = jax.random.split(rng, 3)
        blocks = []
        for k in jax.random.split(k_blocks, cfg.n_layers):
            k1, k2 = jax.random.split(k)
            blocks.append({
                "ln1": jnp.ones((cfg.d_model,), self.dtype),
                "ln2": jnp.ones((cfg.d_model,), self.dtype),
                "tm": init_rwkv6(k1, cfg, self.dtype),
                "cm": init_rwkv_channel_mix(k2, cfg, self.dtype),
            })
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
        return {
            "embed": jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model),
                                       self.dtype) * 0.02,
            "unembed": jax.random.normal(k_out, (cfg.d_model,
                                                 cfg.vocab_size),
                                         self.dtype)
            * (float(1.0 / np.sqrt(cfg.d_model))),
            "ln_f": jnp.ones((cfg.d_model,), self.dtype),
            "blocks": stacked,
        }

    # state pytree: wkv (L,B,H,hd,hd), x_tm (L,B,D), x_cm (L,B,D)
    def init_cache(self, batch: int, max_len: int = 0):
        cfg = self.cfg
        wkv_shape, xs_shape = rwkv6_state_shapes(cfg, batch)
        L = cfg.n_layers
        return {
            "wkv": jnp.zeros((L,) + wkv_shape, jnp.float32),
            "x_tm": jnp.zeros((L,) + xs_shape, self.dtype),
            "x_cm": jnp.zeros((L,) + xs_shape, self.dtype),
        }

    def _stack_forward(self, params: Params, x: jax.Array, cache):
        cfg = self.cfg

        def body(x, scanned):
            p, wkv, x_tm, x_cm = scanned
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            h, wkv2, x_tm2 = rwkv6_mix(p["tm"], h, cfg, wkv, x_tm)
            x = x + h
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            h, x_cm2 = rwkv_channel_mix(p["cm"], h, x_cm)
            x = x + h
            return x, (wkv2, x_tm2, x_cm2)

        x, (wkv, x_tm, x_cm) = jax.lax.scan(
            body, x, (params["blocks"], cache["wkv"], cache["x_tm"],
                      cache["x_cm"]))
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return x, {"wkv": wkv, "x_tm": x_tm, "x_cm": x_cm}

    def forward(self, params: Params, tokens: jax.Array):
        x = params["embed"][tokens]
        cache = self.init_cache(tokens.shape[0])
        x, _ = self._stack_forward(params, x, cache)
        return x @ params["unembed"], jnp.zeros((), jnp.float32)

    def loss(self, params: Params, batch) -> jax.Array:
        logits, _ = self.forward(params, batch["tokens"])
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][..., None],
                                   axis=-1)[..., 0]
        return nll.mean()

    def prefill(self, params: Params, tokens: jax.Array, cache):
        x = params["embed"][tokens]
        x, cache = self._stack_forward(params, x, cache)
        return x[:, -1, :] @ params["unembed"], cache

    def decode_step(self, params: Params, tokens: jax.Array, cache,
                    pos: jax.Array):
        del pos                      # recurrent state carries position
        x = params["embed"][tokens]  # (B, 1, D)
        x, cache = self._stack_forward(params, x, cache)
        return (x[:, 0, :] @ params["unembed"]), cache
