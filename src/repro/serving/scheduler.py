"""Continuous-batching scheduler with bucketed plan portfolios.

The fixed-batch `ServingEngine` admits a batch, decodes it to completion,
then admits the next — a late arrival waits for the whole batch ahead of
it (head-of-line blocking), and a short request pays for the longest
request it was packed with.  `ContinuousScheduler` replaces that loop
with iteration-level scheduling over a fixed pool of **slots**:

  * every step runs ONE jitted `decode_step` at a fixed (max_batch, 1)
    shape — a single XLA program for the whole run;
  * each slot carries its own timeline (`pos` is a per-slot vector, see
    `models/layers.attention_decode`): a slot still consuming its prompt
    feeds the next prompt token (this *is* chunked prefill, interleaved
    token-by-token with in-flight decodes — a long prompt never stalls
    anyone), a decoding slot feeds the token it just sampled, and a free
    slot feeds a masked dummy;
  * requests join a free slot the step they arrive (admission queue
    ordered by `Request.arrival_s`) and leave the step they finish —
    the next queued request takes over the slot immediately.

The co-execution twist is the **plan portfolio** (`repro.
compile_portfolio`): one `CoexecPlan` per (batch, seq) bucket.  Each
step selects the smallest bucket covering the live (active-slots,
max-position) shape and charges the step to that plan; per-bucket
fidelity is recorded to the `MeasurementStore`, watched by one
`measure.DriftMonitor` per bucket, and a triggered monitor replans the
bucket **in place** (`CompiledNetwork.replan` on a calibrator fit over
the trailing record window), so a mid-run thermal throttle converges to
a repriced plan without a restart.

Time: `clock="virtual"` (default) advances by each step's selected-plan
cost — deterministic, host-independent, and the clock the serving bench
compares scheduler-vs-fixed-batch under; `clock="wall"` uses the host
stopwatch.  `FixedBatchReference` replays the fixed-batch engine's
admission/batching semantics under the same virtual clock and a single
plan — the baseline the portfolio scheduler must beat.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.engine import Completion, Request

#: per-step cost (seconds) charged by the virtual clock when no portfolio
#: is attached (a bare scheduler still reports latency percentiles)
DEFAULT_STEP_COST_S = 1e-3


@dataclasses.dataclass
class SchedulerConfig:
    """Knobs of the continuous scheduler (all host-side)."""

    max_batch: int = 4            # slot count = decode batch width
    max_len: int = 128            # per-slot cache length
    clock: str = "virtual"        # "virtual" | "wall"
    seed: int = 0
    fidelity_every: int = 16      # plan-execution cadence, in steps
    fidelity_window: int = 4      # trailing reports a replan's fit sees
    drift_threshold: float = 0.35
    drift_hysteresis: float = 0.15
    drift_cooldown: int = 6

    def __post_init__(self):
        if self.clock not in ("virtual", "wall"):
            raise ValueError(f"unknown clock {self.clock!r}; "
                             f"choices: ['virtual', 'wall']")


@dataclasses.dataclass
class ThrottleSim:
    """Simulated mid-run slowdown (thermal throttle): from `at_s` on the
    scheduler clock, every recorded plan-execution wall time is scaled by
    `scale` — the drift the monitors must catch and replan away."""

    at_s: float
    scale: float = 1.8


@dataclasses.dataclass
class ReplanEvent:
    """One in-place bucket replan, with fidelity error before/after."""

    bucket: str
    time_s: float
    step: int
    old_key: str
    new_key: str
    predicted_gain_us: float
    changes: int
    pre_fidelity: float                  # mean |log(wall/pred)|, trailing
    post_fidelity: Optional[float] = None  # filled by the next execution

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class RequestStats:
    rid: int
    arrival_s: float
    first_token_s: float
    done_s: float
    n_tokens: int

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.done_s - self.arrival_s

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["ttft_s"] = self.ttft_s
        d["latency_s"] = self.latency_s
        return d


def _percentile(values: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(values, np.float64), q)) \
        if values else 0.0


@dataclasses.dataclass
class SchedulerReport:
    """Traffic-level outcome of one scheduler run."""

    completions: List[Completion]
    stats: List[RequestStats]
    duration_s: float
    steps: int
    total_tokens: int
    bucket_switches: int
    bucket_steps: Dict[str, int]
    replan_events: List[ReplanEvent]
    clock: str

    @property
    def tokens_per_s(self) -> float:
        return self.total_tokens / self.duration_s if self.duration_s else 0.0

    def latency_p(self, q: float) -> float:
        return _percentile([s.latency_s for s in self.stats], q)

    def ttft_p(self, q: float) -> float:
        return _percentile([s.ttft_s for s in self.stats], q)

    def to_json(self) -> Dict[str, Any]:
        return {
            "clock": self.clock,
            "requests": len(self.stats),
            "duration_s": self.duration_s,
            "steps": self.steps,
            "total_tokens": self.total_tokens,
            "tokens_per_s": self.tokens_per_s,
            "latency_p50_s": self.latency_p(50),
            "latency_p99_s": self.latency_p(99),
            "ttft_p50_s": self.ttft_p(50),
            "ttft_p99_s": self.ttft_p(99),
            "bucket_switches": self.bucket_switches,
            "bucket_steps": dict(self.bucket_steps),
            "replan_events": [e.to_json() for e in self.replan_events],
        }

    def summary(self) -> str:
        lines = [
            f"served {len(self.stats)} requests / {self.total_tokens} "
            f"tokens in {self.duration_s:.3f}s ({self.clock} clock) — "
            f"{self.tokens_per_s:.1f} tok/s over {self.steps} steps",
            f"  latency p50 {self.latency_p(50):.3f}s  "
            f"p99 {self.latency_p(99):.3f}s | ttft p50 "
            f"{self.ttft_p(50):.3f}s  p99 {self.ttft_p(99):.3f}s",
        ]
        if self.bucket_steps:
            per = " ".join(f"{tag}:{n}" for tag, n in
                           sorted(self.bucket_steps.items()))
            lines.append(f"  bucket switches: {self.bucket_switches} "
                         f"(steps per bucket: {per})")
        for e in self.replan_events:
            post = (f"{e.post_fidelity:.3f}" if e.post_fidelity is not None
                    else "pending")
            lines.append(
                f"  replan [{e.bucket}] @ {e.time_s:.3f}s: "
                f"{e.changes} ops moved, predicted gain "
                f"{e.predicted_gain_us:.1f} us, fidelity err "
                f"{e.pre_fidelity:.3f} -> {post}")
        return "\n".join(lines)


class _Slot:
    """One in-flight request bound to a batch row."""

    __slots__ = ("req", "pos", "out", "cur", "admitted_s", "first_token_s")

    def __init__(self, req: Request, now: float):
        self.req = req
        self.pos = 0                  # next cache position to write
        self.out: List[int] = []
        self.cur: Optional[int] = None  # last sampled token
        self.admitted_s = now
        self.first_token_s: Optional[float] = None

    @property
    def prefilling(self) -> bool:
        return self.pos < len(self.req.prompt)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.req.max_new_tokens


class ContinuousScheduler:
    """Iteration-level scheduler over a fixed slot pool (see module doc).

    `model` must support per-slot position vectors
    (`model.per_slot_pos`, the GQA attention path) — each slot runs its
    own timeline in the shared cache, which is what makes join/evict
    correct without any re-prefill or padding.
    """

    def __init__(self, cfg, model, params, *,
                 config: Optional[SchedulerConfig] = None,
                 portfolio=None, measurement_store=None,
                 throttle: Optional[ThrottleSim] = None,
                 plan_cache=None):
        import jax

        if not getattr(model, "per_slot_pos", False):
            raise ValueError(
                "ContinuousScheduler needs per-slot position support "
                "(model.per_slot_pos — the gqa attention path); recurrent "
                "and MLA stacks serve through the fixed-batch "
                "ServingEngine instead")
        self.cfg = cfg
        self.model = model
        self.params = params
        self.config = config or SchedulerConfig()
        self.portfolio = portfolio
        if measurement_store is not None and \
                not hasattr(measurement_store, "append"):
            from repro.measure import MeasurementStore
            measurement_store = MeasurementStore(measurement_store)
        self.store = measurement_store
        self.throttle = throttle
        self.plan_cache = plan_cache   # replanned plans land here (None =
        #                                the compile-time default cache dir)
        self.rng = jax.random.PRNGKey(self.config.seed)
        self._decode = jax.jit(model.decode_step)
        # per-bucket drift state (portfolio mode)
        self._monitors: Dict[Any, Any] = {}
        self._recent_reports: Dict[Any, List[Any]] = {}
        self._fid_log: Dict[Any, List[float]] = {}
        self.replan_events: List[ReplanEvent] = []

    # -------------------------------------------------------------- fidelity
    def _monitor(self, bucket):
        if bucket not in self._monitors:
            from repro.measure import DriftMonitor
            c = self.config
            self._monitors[bucket] = DriftMonitor(
                threshold=c.drift_threshold, hysteresis=c.drift_hysteresis,
                window=c.fidelity_window, baseline=c.fidelity_window,
                cooldown=c.drift_cooldown)
        return self._monitors[bucket]

    def _throttle_scale(self, now: float) -> float:
        if self.throttle is not None and now >= self.throttle.at_s:
            return self.throttle.scale
        return 1.0

    def _profile_scaled(self, compiled, now: float):
        """One steady-state plan execution with any active throttle
        applied to the recorded wall times (the metrics on the report
        compute lazily from the timings, so scaling propagates)."""
        report = compiled.profile(warmup=True)
        scale = self._throttle_scale(now)
        if scale != 1.0:
            for t in report.timings:
                t.wall_us *= scale
        return report

    def _observe_fidelity(self, bucket, compiled, now: float,
                          step: int) -> None:
        """Execute the bucket's plan once, append the (throttle-scaled)
        records to the store, and feed the bucket's drift monitor —
        replanning in place when it fires."""
        report = self._profile_scaled(compiled, now)
        if self.store is not None:
            self.store.append(report)
        window = self._recent_reports.setdefault(bucket, [])
        window.append(report)
        del window[:-self.config.fidelity_window]
        self._fid_log.setdefault(bucket, []).append(report.fidelity_error())
        ratio = report.mean_log_ratio()
        if ratio is None:
            return
        if self._monitor(bucket).observe(ratio) and \
                self.portfolio is not None and self.portfolio.can_replan():
            self._replan(bucket, compiled, now, step)

    def _replan(self, bucket, compiled, now: float, step: int) -> None:
        """In-place bucket repair, validated before commit.

        The calibrator is fit on the newest half of the record window —
        at trigger time the trailing median has crossed, so the most
        recent reports are the ones describing the drifted regime (older
        ones describe a device state that no longer exists).  Records
        carry the *current plan's* predictions, so when that plan already
        embeds a calibration the fresh fit is composed with it
        (`Calibrator.compose`) to stay valid on raw predictor output.
        The repaired plan is executed once before commit: if its fidelity
        error is not actually lower than the trailing window's (a noise
        trigger), the old plan keeps serving and only the monitor resets."""
        from repro.measure import Calibrator
        window = self._recent_reports.get(bucket, [])
        recent = window[-max(2, self.config.fidelity_window // 2):]
        records = [t for rep in recent for t in rep.timings]
        if not records:
            return
        cal = Calibrator.fit(records).compose(
            getattr(compiled, "calibration", None))
        if self.plan_cache is not None:
            new_compiled, diff = compiled.replan(cal, cache=self.plan_cache)
        else:
            new_compiled, diff = compiled.replan(cal)
        # static verification gate: a calibration-induced illegal decision
        # must never reach the slot pool.  On error diagnostics the old
        # plan keeps serving; the monitor still resets so the same drifted
        # window cannot re-trigger a doomed replan every step.
        from repro.analysis import errors as diag_errors, verify_plan
        bad = diag_errors(verify_plan(new_compiled.plan, stats=False))
        if bad:
            import logging
            logging.getLogger("repro.serving").warning(
                "replan for %s rejected by static verification: %s",
                bucket.tag, bad[0])
            self._monitor(bucket).reset()
            self._recent_reports[bucket] = []
            self._fid_log[bucket] = []
            return
        pre = float(np.mean(self._fid_log[bucket]
                            [-self.config.fidelity_window:]))
        post_report = self._profile_scaled(new_compiled, now)
        post = post_report.fidelity_error()
        # new baseline either way: the drifted window must not re-trigger
        self._monitor(bucket).reset()
        self._recent_reports[bucket] = []
        self._fid_log[bucket] = []
        if post >= pre:
            return                     # repair didn't help: keep old plan
        self.portfolio.replace(bucket, new_compiled)
        if self.store is not None:
            self.store.append(post_report)
        self._recent_reports[bucket] = [post_report]
        self._fid_log[bucket] = [post]
        self.replan_events.append(ReplanEvent(
            bucket=bucket.tag, time_s=now, step=step,
            old_key=diff.old_key, new_key=diff.new_key,
            predicted_gain_us=diff.predicted_gain_us,
            changes=len(diff.changes), pre_fidelity=pre,
            post_fidelity=post))

    # ------------------------------------------------------------------ run
    def run(self, requests: List[Request]) -> SchedulerReport:
        import jax.numpy as jnp

        from repro.serving.engine import sample_tokens

        cfg = self.config
        for r in requests:
            need = len(r.prompt) + r.max_new_tokens
            if need > cfg.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt + max_new_tokens = {need} "
                    f"exceeds max_len={cfg.max_len}")
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        pending.reverse()                      # pop() from the tail
        slots: List[Optional[_Slot]] = [None] * cfg.max_batch
        cache = self.model.init_cache(cfg.max_batch, cfg.max_len)

        completions: List[Completion] = []
        stats: List[RequestStats] = []
        now = 0.0
        start_s = now
        steps = 0
        total_tokens = 0
        bucket_switches = 0
        bucket_steps: Dict[str, int] = {}
        last_bucket = None
        wall_anchor = time.perf_counter()

        while pending or any(s is not None for s in slots):
            # ---------------------------------------------------- admission
            if all(s is None for s in slots) and pending and \
                    pending[-1].arrival_s > now:
                now = pending[-1].arrival_s    # idle: fast-forward
            for i in range(cfg.max_batch):
                if slots[i] is None and pending and \
                        pending[-1].arrival_s <= now:
                    slots[i] = _Slot(pending.pop(), now)
            active = [i for i, s in enumerate(slots) if s is not None]
            if not active:
                continue

            # ---------------------------------------------- bucket selection
            bucket, compiled = None, None
            if self.portfolio is not None:
                live_b = len(active)
                live_seq = max(slots[i].pos + 1 for i in active)
                bucket, compiled = self.portfolio.select(live_b, live_seq)
                tag = bucket.tag
                bucket_steps[tag] = bucket_steps.get(tag, 0) + 1
                if last_bucket is not None and bucket != last_bucket:
                    bucket_switches += 1
                last_bucket = bucket

            # ------------------------------------------------- decode step
            toks = np.zeros((cfg.max_batch, 1), np.int32)
            pos = np.zeros((cfg.max_batch,), np.int32)
            temps = np.zeros((cfg.max_batch,), np.float32)
            for i in active:
                s = slots[i]
                if s.prefilling:
                    toks[i, 0] = int(s.req.prompt[s.pos])
                else:
                    toks[i, 0] = s.cur
                    temps[i] = s.req.temperature
                pos[i] = s.pos
            logits, cache = self._decode(self.params, jnp.asarray(toks),
                                         cache, jnp.asarray(pos))
            # sampling temperature applies only to rows past their prompt;
            # rows mid-prefill (and free rows) stay greedy so they never
            # consume rng — admission order cannot shift another request's
            # sampled tokens
            sampled, self.rng = sample_tokens(self.rng, logits, temps)
            steps += 1

            # ----------------------------------------------------- advance
            if cfg.clock == "virtual":
                if compiled is not None and \
                        compiled.plan.end_to_end_us is not None:
                    now += compiled.plan.end_to_end_us * 1e-6
                else:
                    now += DEFAULT_STEP_COST_S
            else:
                t1 = time.perf_counter()
                now += t1 - wall_anchor
                wall_anchor = t1

            for i in active:
                s = slots[i]
                emits = s.pos >= len(s.req.prompt) - 1   # last prompt tok
                s.pos += 1
                if not emits:
                    continue
                s.cur = int(sampled[i])
                s.out.append(s.cur)
                total_tokens += 1
                if s.first_token_s is None:
                    s.first_token_s = now
                if s.done:
                    completions.append(Completion(s.req.rid, s.out))
                    stats.append(RequestStats(
                        rid=s.req.rid, arrival_s=s.req.arrival_s,
                        first_token_s=s.first_token_s, done_s=now,
                        n_tokens=len(s.out)))
                    slots[i] = None

            # ---------------------------------------------------- fidelity
            if compiled is not None and steps % cfg.fidelity_every == 0:
                self._observe_fidelity(bucket, compiled, now, steps)

        return SchedulerReport(
            completions=completions, stats=stats,
            duration_s=now - start_s, steps=steps,
            total_tokens=total_tokens, bucket_switches=bucket_switches,
            bucket_steps=bucket_steps, replan_events=self.replan_events,
            clock=cfg.clock)


class FixedBatchReference:
    """The fixed-batch engine's scheduling semantics replayed under the
    scheduler's virtual clock with ONE plan for every step — the baseline
    `benchmarks/serving_bench.py` compares the portfolio scheduler
    against.

    Token-for-token it mirrors `ServingEngine.run`: requests are packed
    into arrival-order batches of `max_batch`, each batch bulk-prefills
    to the longest member's length (padded rows pay for pad positions)
    and decodes until its longest member finishes, and the next batch
    cannot start before the previous one ends (head-of-line blocking).
    Costs come from the single `CompiledNetwork` — the portfolio
    degenerate case bucket-count = 1 — so the comparison isolates what
    bucketed plans + iteration-level scheduling buy at identical arrival
    traffic.  No model forward runs: the reference prices schedules, it
    does not sample tokens (`run` returns stats, not completions).
    """

    def __init__(self, compiled, *, max_batch: int = 4):
        self.compiled = compiled
        self.max_batch = max_batch

    def _step_cost_s(self) -> float:
        e2e = self.compiled.plan.end_to_end_us
        return e2e * 1e-6 if e2e is not None else DEFAULT_STEP_COST_S

    def run(self, requests: List[Request]) -> SchedulerReport:
        order = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        cost = self._step_cost_s()
        now = 0.0
        stats: List[RequestStats] = []
        steps = 0
        total_tokens = 0
        for i in range(0, len(order), self.max_batch):
            batch = order[i:i + self.max_batch]
            # the engine blocks until the whole batch has arrived, then
            # until the previous batch drained
            now = max(now, max(r.arrival_s for r in batch))
            t = max(len(r.prompt) for r in batch)
            now += t * cost                       # padded bulk prefill
            steps += t
            first_token_s = now
            max_new = max(r.max_new_tokens for r in batch)
            done_at = {}
            for k in range(1, max_new + 1):       # k tokens emitted
                for r in batch:
                    if r.max_new_tokens == k:
                        done_at[r.rid] = now + (k - 1) * cost
                if k < max_new:
                    steps += 1
            now += (max_new - 1) * cost           # decode to the longest
            for r in batch:
                done = done_at.get(r.rid, now)
                stats.append(RequestStats(
                    rid=r.rid, arrival_s=r.arrival_s,
                    first_token_s=first_token_s, done_s=done,
                    n_tokens=r.max_new_tokens))
                total_tokens += r.max_new_tokens
        return SchedulerReport(
            completions=[], stats=stats, duration_s=now, steps=steps,
            total_tokens=total_tokens, bucket_switches=0,
            bucket_steps={}, replan_events=[], clock="virtual")


def poisson_requests(n: int, *, rate: float, vocab_size: int,
                     prompt_lens=(4, 8, 16), max_new=(4, 8, 16),
                     temperatures=(0.0, 0.0, 0.7), seed: int = 0
                     ) -> List[Request]:
    """Synthetic traffic: `n` requests with exponential inter-arrival
    times at `rate` req/s and mixed prompt lengths / generation budgets /
    temperatures — the workload generator shared by the serving bench,
    the CLI, and the CI smoke."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out: List[Request] = []
    for rid in range(n):
        t += float(rng.exponential(1.0 / rate))
        out.append(Request(
            rid=rid,
            prompt=rng.integers(1, vocab_size,
                                int(rng.choice(prompt_lens))
                                ).astype(np.int32),
            max_new_tokens=int(rng.choice(max_new)),
            temperature=float(rng.choice(temperatures)),
            arrival_s=t))
    return out
