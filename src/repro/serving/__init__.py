from repro.serving.engine import Completion, Request, ServingEngine
from repro.serving.scheduler import (ContinuousScheduler, FixedBatchReference,
                                     ReplanEvent, SchedulerConfig,
                                     SchedulerReport, ThrottleSim,
                                     poisson_requests)
__all__ = ["Completion", "ContinuousScheduler", "FixedBatchReference",
           "ReplanEvent", "Request", "SchedulerConfig", "SchedulerReport",
           "ServingEngine", "ThrottleSim", "poisson_requests"]
