"""Batched serving engine: request queue -> prefill -> decode loop.

A deliberately small but real continuous-batching engine: requests arrive
with prompts, get packed into a fixed batch, prefilled once, then decoded
step-by-step with greedy/temperature sampling until max tokens.  The same
`prefill`/`decode_step` functions are what the dry-run lowers at production
shapes.

An engine can be constructed with a `repro.CompiledNetwork`
(`compiled=...`, the facade artifact — preferred) or a bare `CoexecPlan`
(`coexec_plan=...`, the pre-facade spelling, still supported): a
deployment ships the offline partitioning artifact alongside the model
instead of re-planning at serving time — and the engine *executes* it.
`execute_plan()` lowers the plan's op graph — projection/linear and conv
nodes channel-split, attention/SSM decoder-block nodes through their
registered kernels, residual adds materialized — through `PlanExecutor`
onto the co-execution mesh, keeping the per-node fidelity report on
`engine.last_execution_report` for ops teams to compare executed against
planned latency.  With `compiled=` the engine shares the compiled
network's memoized executor; plans compiled from `graph.from_model`
configs execute the same way the legacy unit-chain plans do.

With `measurement_store=` (a `repro.measure.MeasurementStore` or a
directory path), every `execute_plan` call auto-appends its per-op
`MeasurementRecord`s to the store — the serving fleet becomes the
calibration data source — and `engine.drift` exposes how far the
executed-vs-predicted log-ratio has moved (trailing-window median vs
baseline-window median; the replanning trigger an ops team would alert
on, consumed automatically by `repro.serving.ContinuousScheduler`).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, List, Optional, Tuple

import numpy as np

if TYPE_CHECKING:
    import jax
    from repro.models.config import ModelConfig
    from repro.runtime.executor import ExecutionReport, PlanExecutor
    from repro.runtime.plan import CoexecPlan


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (T,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0           # 0 = greedy
    frames: Optional[np.ndarray] = None  # enc-dec only
    arrival_s: float = 0.0             # admission time (scheduler traffic)


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]


def sample_tokens(rng, logits: jax.Array, temperatures
                  ) -> Tuple[jax.Array, Any]:
    """Per-request sampling shared by the fixed-batch engine and the
    continuous scheduler: row i of `logits` samples at `temperatures[i]`
    (<= 0 = greedy).  Returns (tokens, rng) — the key is split (and thus
    consumed) only when some row actually samples, so all-greedy batches
    are rng-invariant."""
    import jax
    import jax.numpy as jnp
    temps = jnp.asarray(temperatures, jnp.float32)
    if temps.ndim == 0:
        temps = jnp.full((logits.shape[0],), temps)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if not bool(jnp.any(temps > 0.0)):
        return greedy, rng
    rng, sub = jax.random.split(rng)
    safe = jnp.where(temps > 0.0, temps, 1.0)
    sampled = jax.random.categorical(
        sub, logits / safe[:, None], axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0.0, sampled, greedy), rng


class ServingEngine:
    def __init__(self, cfg: ModelConfig, model, params, *,
                 max_batch: int = 4, max_len: int = 128, seed: int = 0,
                 coexec_plan: Optional["CoexecPlan"] = None,
                 compiled=None, measurement_store=None):
        import jax
        self.cfg = cfg
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.rng = jax.random.PRNGKey(seed)
        if compiled is not None and coexec_plan is not None:
            raise ValueError("pass either compiled= (a repro.CompiledNetwork)"
                             " or coexec_plan= (a bare CoexecPlan), not both")
        if compiled is not None:
            if not (hasattr(compiled, "plan") and hasattr(compiled, "target")
                    and hasattr(compiled, "executor")):
                raise TypeError("compiled must be a repro.CompiledNetwork "
                                f"(got {type(compiled).__name__})")
            coexec_plan = compiled.plan
        elif coexec_plan is not None and \
                not hasattr(coexec_plan, "provenance"):
            raise TypeError("coexec_plan must be a repro.runtime CoexecPlan "
                            f"(got {type(coexec_plan).__name__})")
        self.compiled = compiled
        self.coexec_plan = coexec_plan
        if measurement_store is not None and \
                not hasattr(measurement_store, "append"):
            from repro.measure import MeasurementStore
            measurement_store = MeasurementStore(measurement_store)
        self.measurement_store = measurement_store
        self._fidelity_log: List[float] = []   # mean log(wall/pred) per run
        self._plan_executor: Optional["PlanExecutor"] = None
        self.last_execution_report: Optional["ExecutionReport"] = None
        self.last_batch_decode_steps = 0       # decode calls of last batch
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    @property
    def plan_executor(self) -> "PlanExecutor":
        """The runtime lowering of the shipped plan (built on first use;
        shared with the CompiledNetwork's memoized executor when one was
        passed)."""
        if self.coexec_plan is None:
            raise ValueError("engine was constructed without a compiled "
                             "network or coexec_plan")
        if self._plan_executor is None:
            if self.compiled is not None:
                self._plan_executor = self.compiled.executor()
            else:
                from repro.runtime.executor import PlanExecutor
                self._plan_executor = PlanExecutor(self.coexec_plan)
        return self._plan_executor

    def execute_plan(self, x: Optional[jax.Array] = None, *,
                     chain: bool = True,
                     warmup: bool = True) -> Tuple[jax.Array, Any]:
        """Execute the shipped plan on the co-execution mesh.

        Runs every scheduled unit — co-executed projection (linear) and
        conv layers channel-split across the device groups, exclusive ones
        unsplit — and records the executed-vs-predicted fidelity report on
        `self.last_execution_report` (and, when the engine has a
        `measurement_store`, appends the per-op records to it).  Returns
        (output, report).

        `warmup=True` (default) costs one untimed pass before the
        executor's *first* run only (the executor tracks what it already
        executed), so the recorded wall times — the calibration data
        source and the `drift` anchor — measure steady-state execution,
        never tracing + XLA compilation.
        """
        y, report = self.plan_executor.run(x, chain=chain, warmup=warmup)
        self.last_execution_report = report
        ratio = report.mean_log_ratio()
        if ratio is not None:
            self._fidelity_log.append(ratio)
        if self.measurement_store is not None:
            self.measurement_store.append(report)
        return y, report

    @property
    def drift(self) -> Optional[float]:
        """Windowed fidelity drift of the shipped plan: trailing-window
        median of the mean log(wall/pred) fidelity log minus its
        baseline-window median (0.0 = stable, positive = the plan got
        slower than planned — the replanning trigger).  Medians on both
        ends mean a single noisy run — first or latest — cannot poison
        the signal.  None until two executions have been observed."""
        from repro.measure.drift import windowed_drift
        return windowed_drift(self._fidelity_log)

    @property
    def drift_latest_vs_first(self) -> Optional[float]:
        """The pre-windowing drift spelling (latest run minus first run),
        kept for callers that want the raw two-point comparison."""
        if len(self._fidelity_log) < 2:
            return None
        return self._fidelity_log[-1] - self._fidelity_log[0]

    def _sample(self, logits: jax.Array, temperatures) -> jax.Array:
        """Per-request sampling: row i of `logits` samples at
        `temperatures[i]` (<= 0 = greedy), so mixed greedy/temperature
        batches are correct.  All-greedy batches never consume rng."""
        tok, self.rng = sample_tokens(self.rng, logits, temperatures)
        return tok

    def run(self, requests: List[Request]) -> List[Completion]:
        out: List[Completion] = []
        for i in range(0, len(requests), self.max_batch):
            out.extend(self._run_batch(requests[i:i + self.max_batch]))
        return out

    def _run_batch(self, batch: List[Request]) -> List[Completion]:
        import jax.numpy as jnp
        b = len(batch)
        t = max(len(r.prompt) for r in batch)
        toks = np.zeros((b, t), np.int32)
        for i, r in enumerate(batch):
            toks[i, t - len(r.prompt):] = r.prompt     # left-pad
        toks = jnp.asarray(toks)
        # pad-aware attention stacks mask everything before each row's
        # first real token, so a short prompt padded behind a long one
        # decodes exactly as it would alone (RoPE phases are relative —
        # the constant shift cancels); recurrent/MLA stacks keep the
        # legacy shared-timeline semantics
        start = None
        if getattr(self.model, "pad_aware", False):
            start = jnp.asarray(
                np.array([t - len(r.prompt) for r in batch], np.int32))

        cache = self.model.init_cache(b, self.max_len)
        if self.cfg.is_encoder_decoder:
            frames = jnp.asarray(np.stack([
                r.frames if r.frames is not None else
                np.zeros((self.cfg.encoder_seq, self.cfg.d_model),
                         np.float32)
                for r in batch]))
            logits, cache = self._prefill(self.params, toks, cache, frames)
        elif start is not None:
            logits, cache = self._prefill(self.params, toks, cache,
                                          start=start)
        else:
            logits, cache = self._prefill(self.params, toks, cache)

        max_new = max(r.max_new_tokens for r in batch)
        # per-request temperatures: a greedy request stays greedy even when
        # batched behind a temperature-sampling one (batch[0] used to win)
        temps = np.array([r.temperature for r in batch], np.float32)
        generated = [[] for _ in range(b)]
        tok = self._sample(logits, temps)
        for i in range(b):
            generated[i].append(int(tok[i]))
        self.last_batch_decode_steps = 0
        for step in range(1, max_new):
            if all(len(g) >= r.max_new_tokens
                   for g, r in zip(generated, batch)):
                break                   # every request already done
            pos = jnp.int32(t + step - 1)
            if start is not None:
                logits, cache = self._decode(self.params, tok[:, None],
                                             cache, pos, start=start)
            else:
                logits, cache = self._decode(self.params, tok[:, None],
                                             cache, pos)
            self.last_batch_decode_steps += 1
            tok = self._sample(logits, temps)
            for i in range(b):
                if len(generated[i]) < batch[i].max_new_tokens:
                    generated[i].append(int(tok[i]))
        return [Completion(r.rid, g) for r, g in zip(batch, generated)]
